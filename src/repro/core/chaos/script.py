"""Deterministic fault schedules for chaos drills.

A :class:`ChaosScript` is a sorted list of timed :class:`ChaosAction`
entries bound to a live serving target through the target's ``on_step``
hook.  Everything is a pure function of (script, seed, step clock): victim
selection draws from a seeded generator, actions fire on the first step at
or past their timestamp, and :meth:`ChaosScript.reset` rewinds the whole
schedule for a byte-identical re-run -- the property the audit-determinism
gate in ``benchmarks/chaos_drills.py`` relies on.

The target is duck-typed.  ``webhook`` actions need ``fire_webhook(name,
now)`` (:class:`~repro.serving.fleet.FleetBackend`, or a
:class:`~repro.core.scaling.ScalingController` via an adapter); ``kill`` /
``corr_kill`` actions additionally need ``pool.serving`` (replicas with an
``rix``) and ``kill_replica(replica, now)`` -- i.e. a fleet of real engines.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

#: action kinds, in intra-step execution order (kills land before operator
#: intent so a webhook fired "at the same instant" sees the loss)
KINDS = ("kill", "corr_kill", "webhook")


@dataclass(frozen=True)
class ChaosAction:
    """One timed fault in a drill script.

    * ``kill`` -- abrupt loss of ``count`` live replicas; victims are a
      seeded uniform draw over the serving set (in-flight work restarts
      from scratch, same semantics as eviction).
    * ``corr_kill`` -- correlated loss of ``ceil(frac * live)`` replicas in
      a single tick, modelling an AZ / rack failure domain.
    * ``webhook`` -- operator intent lands mid-incident: fire the scaling
      group's webhook ``name``.  In convergence mode its floors apply to
      the desired state *immediately*, superseding any in-flight retry or
      backoff for the affected pools.
    """

    at_s: float
    kind: str
    count: int = 1
    frac: float = 0.5
    name: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown action kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.at_s < 0:
            raise ValueError(f"at_s={self.at_s} must be >= 0")
        if self.count < 1:
            raise ValueError(f"count={self.count} must be >= 1")
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"frac={self.frac} must be in (0, 1]")
        if self.kind == "webhook" and not self.name:
            raise ValueError("webhook action needs a name")


class ChaosScript:
    """Seeded, replayable incident schedule.

    Pass :meth:`on_step` as the target's ``on_step`` hook (both
    ``FleetBackend`` and ``ElasticCluster`` call it as ``hook(target, t)``
    once per step, after capacity lands and before arrivals).  Every action
    due at or before the current step fires exactly once, in timestamp
    order (ties break by :data:`KINDS` order, then webhook name);
    :attr:`fired` records what actually happened -- kill victims by
    ``rix`` -- for assertions and drill reports.
    """

    def __init__(self, actions, *, seed: int = 0):
        acts = tuple(actions)
        for a in acts:
            if not isinstance(a, ChaosAction):
                raise TypeError(f"expected ChaosAction, got {type(a).__name__}")
        self.actions = tuple(sorted(
            acts, key=lambda a: (a.at_s, KINDS.index(a.kind), a.name)))
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._cursor = 0
        self.fired: list[dict] = []

    def reset(self) -> None:
        """Rewind for a byte-identical re-run (same seed, same draws)."""
        self._rng = np.random.default_rng(self.seed)
        self._cursor = 0
        self.fired = []

    @property
    def done(self) -> bool:
        return self._cursor >= len(self.actions)

    def on_step(self, target, now: float) -> None:
        while (self._cursor < len(self.actions)
               and self.actions[self._cursor].at_s <= now):
            action = self.actions[self._cursor]
            self._cursor += 1
            self._fire(target, action, now)

    def _fire(self, target, action: ChaosAction, now: float) -> None:
        if action.kind == "webhook":
            target.fire_webhook(action.name, now)
            self.fired.append({"t": now, "kind": "webhook",
                               "name": action.name})
            return
        live = sorted(target.pool.serving, key=lambda r: r.rix)
        if action.kind == "kill":
            k = min(action.count, len(live))
        else:                                   # corr_kill: failure domain
            k = min(max(math.ceil(action.frac * len(live)), 1), len(live))
        picks = (self._rng.choice(len(live), size=k, replace=False)
                 if k else np.empty(0, np.int64))
        victims = [live[i] for i in sorted(int(p) for p in picks)]
        for rep in victims:
            target.kill_replica(rep, now)
        self.fired.append({"t": now, "kind": action.kind,
                           "victims": [r.rix for r in victims]})


__all__ = ["KINDS", "ChaosAction", "ChaosScript"]
