"""The drill harness: reference run, faulted run, invariant verdict.

A :class:`ChaosDrill` owns one scripted incident end to end: it builds a
fault-free *reference* backend and runs it to completion, rewinds the
script, builds the *faulted* backend with the script wired into its
``on_step`` hook (cheap invariants -- duplicate completions, KV page
conservation -- checked after every step), runs it, then applies the full
invariant battery from :mod:`.invariants` and folds everything into a
:class:`DrillReport`.

The backend factory is duck-typed: it is called as
``make_backend(on_step=..., audit_path=...)`` and must return an object
with ``run()``, ``requests`` / ``completed`` (objects carrying ``rid``),
a ``pool`` of real replicas (for KV checks; targets without one are
skipped via ``getattr``), and a ``controller`` exposing the capacity plan
for the audit final-state cross check --
:class:`~repro.serving.fleet.FleetBackend` is the canonical target.
Elastic-simulator incidents instead compose
:class:`~repro.core.convergence.faults.ScriptedFaults` (process-level
loss/brownout windows) with :func:`~repro.core.chaos.invariants.check_audit`
directly; see ``benchmarks/chaos_drills.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .invariants import (
    Violation, check_audit, check_exactly_once, check_kv_conservation,
    check_outputs_match,
)
from .script import ChaosScript


@dataclass
class DrillReport:
    """Outcome of one drill: what fired, what broke, what completed."""

    name: str
    violations: list[Violation]
    fired: list[dict]                   # script actions that actually ran
    n_completed: int
    n_reference: int
    audit_path: str | None = None
    extra: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        verdict = ("OK" if self.ok
                   else f"{len(self.violations)} violation(s)")
        lines = [f"drill {self.name!r}: {verdict} "
                 f"({len(self.fired)} actions, {self.n_completed}/"
                 f"{self.n_reference} requests)"]
        lines.extend(f"  - {v}" for v in self.violations)
        return "\n".join(lines)


class ChaosDrill:
    """One scripted incident, checked for observational equivalence.

    ``make_backend(on_step=..., audit_path=...)`` must build a *fresh*
    target each call -- requests are mutable (the engine fills outputs in
    place), so reference and faulted passes cannot share them.
    """

    def __init__(self, name: str, make_backend, script: ChaosScript, *,
                 audit_path: str | None = None, per_step_checks: bool = True):
        self.name = name
        self.make_backend = make_backend
        self.script = script
        self.audit_path = audit_path
        self.per_step_checks = per_step_checks

    def run(self) -> DrillReport:
        reference = self.make_backend(on_step=None, audit_path=None)
        reference.run()

        self.script.reset()
        step_violations: list[Violation] = []

        def hook(backend, now):
            self.script.on_step(backend, now)
            if not self.per_step_checks:
                return
            rids = [r.rid for r in backend.requests]
            step_violations.extend(
                check_exactly_once(rids, backend.completed, final=False))
            pool = getattr(backend, "pool", None)
            if pool is not None:
                step_violations.extend(check_kv_conservation(pool))

        faulted = self.make_backend(on_step=hook, audit_path=self.audit_path)
        faulted.run()

        violations = list(step_violations)
        violations += check_exactly_once(
            [r.rid for r in faulted.requests], faulted.completed)
        violations += check_outputs_match(faulted.completed,
                                          reference.completed)
        pool = getattr(faulted, "pool", None)
        if pool is not None:
            violations += check_kv_conservation(pool, drained=True)
        if self.audit_path is not None:
            plan = faulted.controller.plan
            final_state = {p.name: {"live": plan.live_of(p.name),
                                    "pending": plan.pending_of(p.name)}
                           for p in plan}
            violations += check_audit(self.audit_path, final_state)

        # a per-step breakage repeats every later step; report each once
        deduped = list(dict.fromkeys(violations))
        return DrillReport(
            name=self.name,
            violations=deduped,
            fired=list(self.script.fired),
            n_completed=len(faulted.completed),
            n_reference=len(reference.completed),
            audit_path=self.audit_path,
        )


__all__ = ["ChaosDrill", "DrillReport"]
