"""Invariant checkers: what "recovered correctly" means, mechanically.

Each checker returns a list of :class:`Violation` records (empty == the
invariant holds) instead of raising, so a drill can run every check and
report the full set of breakages at once.  The four invariants together say
a faulted run is *observationally equivalent* to a fault-free one:

1. exactly-once   -- no admitted request is lost or duplicated;
2. bit-identical  -- surviving outputs match the no-fault reference
                     token-for-token;
3. KV conservation -- the page free list balances on every live engine and
                     drained engines handed every page back;
4. audit replay   -- the sealed log loads clean, capacity replay matches,
                     and re-running the pure planner over the logged inputs
                     reproduces the converger's decisions byte-for-byte
                     with no step against a superseded generation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

from ..convergence.audit import (
    AuditIntegrityError, AuditLog, replay, verify_plan_replay,
)


@dataclass(frozen=True)
class Violation:
    """One broken invariant: a short id plus a human-readable account."""

    invariant: str
    detail: str

    def __str__(self) -> str:
        return f"{self.invariant}: {self.detail}"


def check_exactly_once(admitted_rids: Iterable[int], completed,
                       *, final: bool = True) -> list[Violation]:
    """Every admitted request id completes exactly once.

    ``completed`` is the run's completion list (requests with ``rid``,
    ``output`` and ``done_s``).  With ``final=False`` (mid-drill) only
    duplicates and phantom completions are violations -- requests still in
    flight are expected; with ``final=True`` a missing completion is a lost
    request.
    """
    violations: list[Violation] = []
    seen: dict[int, int] = {}
    for r in completed:
        seen[r.rid] = seen.get(r.rid, 0) + 1
        if final and (r.done_s is None or not r.output):
            violations.append(Violation(
                "exactly_once",
                f"request {r.rid} completed without "
                f"{'a done timestamp' if r.done_s is None else 'output'}"))
    admitted = set(admitted_rids)
    for rid in sorted(admitted):
        n = seen.pop(rid, 0)
        if n == 0 and final:
            violations.append(Violation(
                "exactly_once", f"request {rid} admitted but never "
                "completed (lost in a kill/drain)"))
        elif n > 1:
            violations.append(Violation(
                "exactly_once", f"request {rid} completed {n} times "
                "(re-admission duplicated it)"))
    for rid, n in sorted(seen.items()):
        violations.append(Violation(
            "exactly_once",
            f"request {rid} completed {n}x but was never admitted"))
    return violations


def check_outputs_match(completed, reference) -> list[Violation]:
    """Faulted-run outputs equal the fault-free reference, token-for-token.

    Kills restart work from scratch and drains migrate committed KV
    bit-identically, so greedy decode must land on the same tokens either
    way; any divergence means recovery corrupted state.
    """
    violations: list[Violation] = []
    ref = {r.rid: tuple(r.output) for r in reference}
    for r in completed:
        want = ref.get(r.rid)
        if want is None:
            violations.append(Violation(
                "bit_identical",
                f"request {r.rid} has no fault-free reference output"))
            continue
        got = tuple(r.output)
        if got != want:
            at = next((i for i, (a, b) in enumerate(zip(got, want))
                       if a != b), min(len(got), len(want)))
            violations.append(Violation(
                "bit_identical",
                f"request {r.rid} diverges from the reference at token "
                f"{at} ({len(got)} vs {len(want)} tokens)"))
    return violations


def check_kv_conservation(pool, *, drained: bool = False) -> list[Violation]:
    """Page accounting balances on every engine that still exists.

    Serving engines must pass the cache's own conservation check (no leak,
    no double-ownership, reservation ledger consistent).  Replicas retired
    via *drain* must have returned every page to the free list -- migration
    may not strand KV.  Killed replicas (retired without the ``draining``
    flag) are skipped: the host is gone, and their in-flight pages were
    re-reserved from scratch elsewhere, which the serving-side checks cover.
    With ``drained=True`` (end of drill, backlog empty) serving engines
    must also be back to a fully free pool.
    """
    violations: list[Violation] = []

    def fully_free(rep) -> bool:
        return rep.eng.kv.n_free == rep.eng.kv.num_pages - 1

    for rep in pool.serving:
        try:
            rep.eng.kv.check_invariants()
        except AssertionError as e:
            violations.append(Violation(
                "kv_conservation", f"replica{rep.rix}: {e}"))
        if drained and not fully_free(rep):
            kv = rep.eng.kv
            violations.append(Violation(
                "kv_conservation",
                f"replica{rep.rix}: {kv.num_pages - 1 - kv.n_free} pages "
                "still held after the drill drained"))
    for rep in pool.retired:
        if rep.draining and not fully_free(rep):
            kv = rep.eng.kv
            violations.append(Violation(
                "kv_conservation",
                f"drained replica{rep.rix} stranded "
                f"{kv.num_pages - 1 - kv.n_free} pages"))
    return violations


def check_audit(path: str, final_state=None) -> list[Violation]:
    """The sealed audit log is intact and replays to the converger's
    actual decisions.

    Three layers: (a) ``load(verify=True)`` -- seal present, count and CRC
    match (a truncated or edited tail is reported, mirroring the checkpoint
    store's ``.ok`` marker); (b) capacity replay equals ``final_state``
    (per-pool ``{"live", "pending"}``) when given; (c)
    :func:`~repro.core.convergence.audit.verify_plan_replay` -- the pure
    planner, re-run on each plan record's logged inputs, reproduces the
    logged steps with no stale-generation plan.
    """
    try:
        records = AuditLog.load(path, verify=True)
    except AuditIntegrityError as e:
        return [Violation("audit_replay", str(e))]
    violations: list[Violation] = []
    if final_state is not None:
        replayed = replay(records)
        for name, want in final_state.items():
            got = replayed.get(name)
            if got != dict(want):
                violations.append(Violation(
                    "audit_replay",
                    f"pool {name!r}: replay gives {got}, plan holds "
                    f"{dict(want)}"))
    checked, mismatches = verify_plan_replay(records)
    for m in mismatches:
        violations.append(Violation(
            "audit_replay",
            f"record {m['index']}: {m['kind']} mismatch -- "
            + (f"plan gen {m['logged']} vs latest desired gen {m['latest']}"
               if m["kind"] == "generation"
               else f"logged {m['logged']} != replayed {m['replayed']}")))
    if checked == 0 and final_state is not None:
        violations.append(Violation(
            "audit_replay", "no plan record carried replayable inputs"))
    return violations


__all__ = [
    "Violation",
    "check_audit",
    "check_exactly_once",
    "check_kv_conservation",
    "check_outputs_match",
]
