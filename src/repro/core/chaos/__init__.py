"""Incident-hardening layer: seeded chaos drills over the convergence plane.

A *drill* replays a scripted incident -- timed replica kills, correlated
multi-replica loss, brownout windows, operator webhooks landing mid-retry --
against a live serving target, then proves recovery was *correct*, not just
eventual, by checking invariants after every step and at drill end:

* **exactly-once** -- every admitted request finishes exactly once; no loss,
  no duplicates (:func:`~repro.core.chaos.invariants.check_exactly_once`);
* **bit-identical** -- the faulted run's outputs match a fault-free reference
  token-for-token (:func:`~repro.core.chaos.invariants.check_outputs_match`);
* **KV conservation** -- the page free list balances across kill / drain /
  respawn (:func:`~repro.core.chaos.invariants.check_kv_conservation`);
* **audit replay** -- the sealed JSONL log loads clean and replaying its
  planner inputs reproduces the converger's decisions byte-for-byte, with no
  step issued against a superseded desired-state generation
  (:func:`~repro.core.chaos.invariants.check_audit`).

:mod:`.script` holds the deterministic fault schedule (a
:class:`~repro.core.chaos.script.ChaosScript` of timed
:class:`~repro.core.chaos.script.ChaosAction` entries -- seeded victim
selection, replayable byte-for-byte); :mod:`.drill` runs the
reference-vs-faulted pair and aggregates violations into a
:class:`~repro.core.chaos.drill.DrillReport`.  Process-level fault windows
(stuck builds, brownouts, flaps) compose via
:class:`~repro.core.convergence.faults.ScriptedFaults` on the same clock.
"""
from .drill import ChaosDrill, DrillReport
from .invariants import (
    Violation, check_audit, check_exactly_once, check_kv_conservation,
    check_outputs_match,
)
from .script import ChaosAction, ChaosScript

__all__ = [
    "ChaosAction",
    "ChaosDrill",
    "ChaosScript",
    "DrillReport",
    "Violation",
    "check_audit",
    "check_exactly_once",
    "check_kv_conservation",
    "check_outputs_match",
]
