"""Discrete-time processor-sharing simulator (paper §IV-A/B) with auto-scaling.

The paper's Algorithm 1 distributes the CPU cycles of one simulation step
egalitarianly among all in-flight tweets, redistributing each tweet's excess to the
still-hungry ones.  That per-tweet loop is exact *water-filling*, implemented once
for every backend in :mod:`repro.core.scaling.service` (sorted struct-of-arrays
in-flight set, payload columns, prefix completion handling).  This engine carries
(post time, sentiment) as the payload columns and is bit-identical to the paper's
loop, ~1000x faster -- what makes the 4.3M-tweet Spain trace x repeat-until-CI
feasible.

The Table III controller mechanics (60 s adaptation frequency, 60 s
provisioning delay, single-unit downscale cap, >= 1 unit floor) live in the
shared :class:`repro.core.scaling.ScalingController`; the per-second sentiment
bins live in a :class:`repro.core.scaling.SignalBus` channel.  The engine is
one :class:`~repro.core.scaling.ScalableBackend` among several -- it only
simulates the processor-sharing service and feeds the control plane.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.core.autoscaler.base import Policy

if TYPE_CHECKING:
    from repro.core.convergence.converger import ConvergerConfig
    from repro.core.convergence.faults import FaultSpec
from repro.core.scaling import (
    ControllerConfig,
    RunReport,
    ScalingController,
    ServiceProcess,
    SignalBus,
    Sla,
    UnitPool,
)
from repro.core.scaling.service import water_level as _water_level  # noqa: F401
from repro.core.simulator.workload import CLASSES, Trace


@dataclass(frozen=True)
class SimConfig:
    """Table III defaults."""

    freq_hz: float = 2.0e9
    starting_units: int = 1
    step_s: float = 1.0
    sla_s: float = 300.0
    adapt_period_s: float = 60.0
    alloc_delay_s: float = 60.0
    max_units: int = 4096                 # safety valve, far above anything reached
    max_input_rate: float | None = None   # tweets/s admitted from the input queue
    queue_in_system: bool = False          # does n_in_system include the ingest queue?
                                           # (the Streams input queue sits upstream of
                                           # the application, so policies cannot see it)
    app_window_s: float = 120.0           # appdata window (§V-B: 120 s beats 60 s)
    drain: bool = True                    # keep simulating until all tweets finish
    pools: tuple[UnitPool, ...] | None = None   # typed capacity (None: one
                                                # on-demand pool from the knobs above)
    sla: Sla | None = None                # per-class deadlines (None: flat sla_s)
    convergence: bool = False             # desired-state reconciliation instead
                                          # of imperative deltas (fault-free:
                                          # bit-for-bit identical)
    converge: "ConvergerConfig | None" = None   # converger timeout/retry knobs
    faults: "tuple[FaultSpec, ...] | None" = None   # seeded fault injection
    audit_path: str | None = None         # mirror the audit log to JSONL


@dataclass
class SimResult(RunReport):
    """Simulator RunReport + the time series the benchmarks/figures need.

    Legacy accessors (``match``, ``delays``, ``cpu_seconds``, ...) map onto the
    shared RunReport schema so pre-redesign call sites keep working.
    """

    util_t: np.ndarray = field(                      # busy fraction per step
        default_factory=lambda: np.empty(0, np.float32))
    in_system_t: np.ndarray = field(                 # tweets in the system per step
        default_factory=lambda: np.empty(0, np.int64))

    @property
    def match(self) -> str:
        return self.workload

    @property
    def delays(self) -> np.ndarray:
        return self.latencies

    @property
    def cpu_seconds(self) -> float:
        return self.unit_seconds

    @property
    def cpu_hours(self) -> float:
        return self.unit_seconds / 3600.0

    @property
    def mean_delay(self) -> float:
        return self.mean_latency_s

    def summary(self) -> dict:
        out = super().summary()
        out.update({
            "match": self.match,
            "violation_pct": 100.0 * self.violation_rate,
            "cpu_hours": self.cpu_hours,
            "mean_delay_s": self.mean_delay,
        })
        return out


class Engine:
    """One simulation run of (trace x policy x config)."""

    def __init__(self, trace: Trace, policy: Policy, config: SimConfig | None = None):
        self.trace = trace
        self.policy = policy
        self.cfg = config or SimConfig()

    def run(self) -> SimResult:
        cfg = self.cfg
        tr = self.trace
        policy = self.policy

        step = cfg.step_s
        n_total = tr.n_tweets
        # Arrival bucketing: tweet i arrives at the step floor(post_time / step).
        arrive_step = (tr.post_time / step).astype(np.int64)
        duration_steps = int(tr.duration / step)

        # in-flight set: the shared water-filling core, carrying (post time,
        # sentiment, tweet class) payload columns through the sorted arrays
        proc = ServiceProcess({"post": np.float64, "sent": np.float32,
                               "cls": np.int8})

        # input queue (only used when max_input_rate caps admission)
        q_head = 0          # first not-yet-admitted tweet index (arrival order)
        n_arrived = 0

        # completed-tweet accounting
        delays = np.zeros(n_total, dtype=np.float64)
        done_cls = np.zeros(n_total, dtype=np.int8)   # class of the i-th completion
        n_done = 0
        # app-signal channel: per-second bins of completed tweets, by POST time
        # (§V-B: "it is not the time the tweet is done being processed that is used
        #  ... but the tweets post time").
        nbins = duration_steps + 2
        bus = SignalBus(("sentiment",), bin_s=step, horizon_bins=nbins)
        ctrl = ScalingController(
            policy,
            ControllerConfig(
                adapt_period_s=cfg.adapt_period_s,
                provision_delay_s=cfg.alloc_delay_s,
                max_units=cfg.max_units,
                step_s=step,
                app_window_s=cfg.app_window_s,
                signal_channel="sentiment",
                pools=cfg.pools,
                convergence=cfg.convergence,
                converge=cfg.converge,
                faults=cfg.faults,
                audit_path=cfg.audit_path,
            ),
            bus,
            starting_units=cfg.starting_units,
        )
        self.controller = ctrl      # post-run inspection (audit log, meters)

        units_hist: list[int] = []
        util_hist: list[float] = []
        insys_hist: list[int] = []

        t_step = 0
        max_steps = duration_steps + 200_000   # drain guard

        while True:
            now = t_step * step
            units = ctrl.on_step_start(now)

            # ---- admit new tweets -------------------------------------------------
            if t_step < duration_steps:
                hi = np.searchsorted(arrive_step, t_step, side="right")
                new_lo, new_hi = n_arrived, hi
                n_arrived = hi
            else:
                new_lo = new_hi = n_arrived
            # input-rate cap: admit from queue head up to max_input_rate * step
            if cfg.max_input_rate is None:
                adm_lo, adm_hi = new_lo, new_hi
                q_head = new_hi
            else:
                budget = int(cfg.max_input_rate * step)
                adm_lo = q_head
                adm_hi = min(n_arrived, q_head + budget)
                q_head = adm_hi
            k_new = adm_hi - adm_lo
            if k_new > 0:
                # zero-demand tweets (PE1 discards) complete instantly
                instant = proc.admit(tr.cycles[adm_lo:adm_hi],
                                     post=tr.post_time[adm_lo:adm_hi],
                                     sent=tr.sentiment[adm_lo:adm_hi],
                                     cls=tr.class_id[adm_lo:adm_hi])
                if instant is not None:
                    k0 = instant["post"].size
                    delays[n_done : n_done + k0] = (now + step) - instant["post"]
                    done_cls[n_done : n_done + k0] = instant["cls"]
                    n_done += k0
                    bus.record("sentiment", instant["post"], instant["sent"])

            L = len(proc)
            insys_hist.append(L + (n_arrived - q_head) if cfg.queue_in_system else L)

            # ---- distribute cycles (Algorithm 1, exact water-filling) ------------
            capacity = units * cfg.freq_hz * step
            sr = proc.step(capacity)
            if sr.n_finished > 0:
                fin_post = sr.finished["post"]
                delays[n_done : n_done + sr.n_finished] = (now + step) - fin_post
                done_cls[n_done : n_done + sr.n_finished] = sr.finished["cls"]
                n_done += sr.n_finished
                bus.record("sentiment", fin_post, sr.finished["sent"])
            util = sr.busy
            units_hist.append(units)
            util_hist.append(util)

            # ---- adapt (Table III mechanics live in the shared controller) --------
            ctrl.note_step(util, new_hi - new_lo)
            ctrl.maybe_adapt(time=now + step, n_in_system=insys_hist[-1])

            t_step += 1
            done_with_arrivals = t_step >= duration_steps and q_head >= n_total
            if done_with_arrivals and (len(proc) == 0 or not cfg.drain):
                break
            if t_step >= max_steps:
                raise RuntimeError(
                    f"simulation failed to drain after {max_steps} steps "
                    f"({len(proc)} tweets left, {units} units)"
                )

        units_arr = np.asarray(units_hist, dtype=np.int64)
        class_names = np.array([c.name for c in CLASSES])
        return SimResult(
            backend="simulator",
            workload=tr.match.name,
            policy=policy.describe(),
            sla_s=cfg.sla_s,
            latencies=delays[:n_done],
            unit_seconds=float(units_arr.sum() * step),
            units_t=units_arr,
            n_decisions_up=ctrl.n_up,
            n_decisions_down=ctrl.n_down,
            unit_name="cpu",
            decisions=ctrl.decision_log,
            sla=cfg.sla,
            classes=class_names[done_cls[:n_done]],
            util_t=np.asarray(util_hist, dtype=np.float32),
            in_system_t=np.asarray(insys_hist, dtype=np.int64),
            **ctrl.plan.report_kwargs(),
        )


def run_scenario(trace: Trace, policy: Policy, config: SimConfig | None = None) -> SimResult:
    return Engine(trace, policy, config).run()


def repeat_until_ci(
    make_policy,
    match: str,
    *,
    config: SimConfig | None = None,
    metric: str = "violation_rate",
    rel_ci: float = 0.10,
    min_reps: int = 3,
    max_reps: int = 8,
    seed0: int = 0,
):
    """Paper §V: 'repeated until the length of the confidence interval with 95%
    confidence was smaller than 10% of the mean'.  Returns (results, reps)."""
    from repro.core.simulator.workload import generate_trace
    from repro.utils.stats import mean_confidence_interval

    results: list[SimResult] = []
    vals: list[float] = []
    for rep in range(max_reps):
        tr = generate_trace(match, seed=seed0 + rep)
        res = run_scenario(tr, make_policy(), config)
        results.append(res)
        vals.append(getattr(res, metric))
        if rep + 1 >= min_reps:
            mean, ci = mean_confidence_interval(vals)
            if mean == 0.0 or ci < rel_ci * abs(mean):
                break
    return results, len(results)


__all__ = ["SimConfig", "SimResult", "Engine", "run_scenario", "repeat_until_ci"]
