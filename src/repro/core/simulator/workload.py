"""Calibrated synthetic reconstruction of the paper's seven match traces.

The original Twitter dumps (2013 FIFA Confederations Cup) are proprietary, so the
generator below reproduces every statistic the paper publishes about them:

* Table II totals / lengths / tweets-per-hour (matched exactly in expectation,
  Poisson arrivals per second);
* Fig 4 burst structure -- friendlies have 1-2 late peaks, group-phase matches a
  handful, the final (Spain) "the highest number of peaks of all games";
* Fig 2/Table I sentiment<->volume coupling -- per-minute mean sentiment correlates
  with the tweet volume of the following minutes with Pearson ~0.79 at lag 0,
  decaying to ~0.70 at lag 10 (validated by benchmarks/table1_correlation.py);
* Fig 3 early-warning structure -- a sentiment-variation spike is planted 1-2 min
  *before* each volume burst, with configurable false-positive / false-negative
  rates ("there are some false positives and a false negative").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import zlib

import numpy as np

from repro.core.simulator.distributions import CLASSES, ServiceModel


@dataclass(frozen=True)
class MatchSpec:
    """One row of Table II."""

    name: str
    total_tweets: int
    length_hours: float
    n_bursts: int            # Fig 4 structure (not published as a number; see Fig 4)
    burst_scale: float       # peak intensity multiplier over the smooth base rate
    bursts_late_only: bool = False   # friendlies: "peaks only close to the end"
    abrupt: bool = False     # mexico: "it happens more abruptly while others have
                             # small increase just before" (SSV-A)
    late_surge: float = 1.0  # sustained second-half elevation (Fig 4: the Spain
                             # final's whole second half runs ~2x the first)

    @property
    def length_seconds(self) -> int:
        return int(round(self.length_hours * 3600.0))


#: Table II, in chronological order.  n_bursts/burst_scale follow Fig 4 qualitatively.
MATCHES: dict[str, MatchSpec] = {
    "england": MatchSpec("england", 370_471, 2.62, 2, 2.0, bursts_late_only=True),
    "france":  MatchSpec("france",  281_882, 2.93, 2, 2.0, bursts_late_only=True),
    "japan":   MatchSpec("japan",   736_171, 4.08, 4, 3.0),
    "mexico":  MatchSpec("mexico",  615_831, 3.79, 4, 7.0, abrupt=True),   # abrupt late peak (SSV-A)

    "italy":   MatchSpec("italy",   518_952, 3.42, 4, 3.0),
    "uruguay": MatchSpec("uruguay", 1_763_353, 3.44, 7, 4.5),
    "spain":   MatchSpec("spain", 4_309_863, 4.18, 10, 4.0, late_surge=2.0),
}


@dataclass
class Trace:
    """A generated match trace (struct-of-arrays, sorted by post time)."""

    match: MatchSpec
    post_time: np.ndarray        # float64 seconds from match start
    class_id: np.ndarray         # int8 index into CLASSES
    cycles: np.ndarray           # float64 service demand
    sentiment: np.ndarray        # float32 score in [0, 1]
    burst_times: np.ndarray      # ground-truth burst onsets (for Fig 3 analysis)
    signal_times: np.ndarray     # planted sentiment-jump windows (incl. false positives)
    per_second_rate: np.ndarray  # the intensity curve lambda(t) (tweets/s)

    @property
    def n_tweets(self) -> int:
        return int(self.post_time.shape[0])

    @property
    def duration(self) -> int:
        return self.match.length_seconds

    def minute_series(self) -> tuple[np.ndarray, np.ndarray]:
        """(mean sentiment per minute, tweet volume per minute) -- Fig 2 / Table I."""
        minutes = (self.post_time // 60.0).astype(np.int64)
        n_min = self.duration // 60
        vol = np.bincount(minutes, minlength=n_min)[:n_min].astype(np.float64)
        s_sum = np.bincount(minutes, weights=self.sentiment, minlength=n_min)[:n_min]
        with np.errstate(invalid="ignore", divide="ignore"):
            sent = np.where(vol > 0, s_sum / np.maximum(vol, 1), np.nan)
        return sent, vol


def _smooth(x: np.ndarray, width: int) -> np.ndarray:
    if width <= 1:
        return x
    kernel = np.ones(width) / width
    return np.convolve(x, kernel, mode="same")


def _base_intensity(rng: np.random.Generator, n: int) -> np.ndarray:
    """Smooth strictly-positive base rate: smoothed log-space random walk with a
    gentle rise over the match (user interest builds up, Fig 4)."""
    walk = np.cumsum(rng.normal(0.0, 0.03, size=n))
    walk = _smooth(walk, 301)
    ramp = np.linspace(-0.15, 0.25, n)
    lam = np.exp(0.55 * walk + ramp)
    return lam / lam.mean()


def _burst_profile(n: int, onset: int, scale: float, rng: np.random.Generator,
                   abrupt: bool = False) -> np.ndarray:
    """Multiplicative burst, exponential decay over 2-5 min (Fig 4).

    Non-abrupt bursts have a wide leading shoulder -- the "small increase just
    before" (SSV-A) that proportional (load) scaling can ride but +1/min threshold
    scaling cannot; ``abrupt`` bursts (mexico) hit with almost no warning."""
    t = np.arange(n, dtype=np.float64)
    rise = (20.0 + 20.0 * rng.random()) if abrupt else (150.0 + 60.0 * rng.random())
    decay = 150.0 + 150.0 * rng.random()
    prof = np.where(
        t < onset,
        np.exp(-((t - onset) ** 2) / (2.0 * rise**2)),       # sharp leading edge
        np.exp(-(t - onset) / decay),                         # slow trailing decay
    )
    return 1.0 + (scale - 1.0) * prof


def generate_trace(
    match: MatchSpec | str,
    seed: int = 0,
    *,
    service_model: ServiceModel | None = None,
    signal_false_negative_rate: float = 0.12,
    n_false_positives: int = 1,
    sentiment_high: float = 0.95,
    minute_noise: float = 0.03,
    vol_noise: float = 0.08,
    tweet_noise: float = 0.12,
) -> Trace:
    """Generate one calibrated trace.

    ``sentiment_high`` is the plateau the sentiment curve saturates to during the
    1-2 min early-warning window before a burst; it is sized so the appdata
    detector's 120 s-window mean rises by >= 0.5 (the paper's trigger) ahead of
    true bursts, while ordinary fluctuation stays well below it.
    """
    if isinstance(match, str):
        match = MATCHES[match]
    sm = service_model or ServiceModel()
    name_tag = zlib.crc32(match.name.encode()) & 0xFFFF   # deterministic across processes
    rng = np.random.default_rng(np.random.SeedSequence([0xA5CA1E, seed, name_tag]))
    n = match.length_seconds

    # ---- intensity curve ----------------------------------------------------------
    lam = _base_intensity(rng, n)
    if match.late_surge != 1.0:
        t_rel = np.arange(n) / n
        lam = lam * (1.0 + (match.late_surge - 1.0) / (1.0 + np.exp(-(t_rel - 0.55) * 18.0)))
        lam = lam / lam.mean()
    lo = 0.55 if match.bursts_late_only else 0.12
    onsets = np.sort(rng.uniform(lo, 0.95, size=match.n_bursts)) * n
    onsets = onsets.astype(np.int64)
    # keep bursts >= 15 min apart so each is an identifiable Fig-3 event whose
    # pre-burst baseline window is clear of the previous event's sentiment tail
    for i in range(1, len(onsets)):
        onsets[i] = max(onsets[i], onsets[i - 1] + 900)
    onsets = onsets[onsets < n - 120]
    for onset in onsets:
        scale = match.burst_scale * (0.6 + 0.8 * rng.random())
        lam *= _burst_profile(n, int(onset), max(scale, 1.5), rng, abrupt=match.abrupt)
    # per-minute volume jitter, independent of sentiment -- this (not sentiment
    # noise) is what keeps the Table I Pearson at ~0.79 instead of ~1.0
    jit = np.repeat(np.exp(rng.normal(0.0, vol_noise, size=n // 60 + 1)), 60)[:n]
    lam *= jit
    lam *= match.total_tweets / lam.sum()

    # ---- arrivals -----------------------------------------------------------------
    counts = rng.poisson(lam)
    total = int(counts.sum())
    sec_of = np.repeat(np.arange(n, dtype=np.float64), counts)
    post_time = sec_of + rng.random(total)
    order = np.argsort(post_time, kind="stable")
    post_time = post_time[order]

    class_id = sm.sample_classes(rng, total)
    cycles = sm.sample_cycles(rng, class_id)

    # ---- sentiment curve ----------------------------------------------------------
    # Base sentiment tracks the *forward-smoothed* volume => Table I's decaying lag
    # correlation ("sentiment at a given time and the number of tweets posted on the
    # following minutes").
    # Sentiment base tracks a wide forward-smoothed volume ("the more intense the
    # sentiment the more tweets are posted", Fig 2): the ~10-min smoothing makes the
    # sentiment<->volume cross-correlation decay *slowly* with lag (Table I), and the
    # slight forward shift puts the maximum at lag 0.
    k = 900
    csum = np.concatenate([[0.0], np.cumsum(lam)])
    idx_hi = np.minimum(np.arange(n) + k, n)
    fwd = (csum[idx_hi] - csum[np.arange(n)]) / np.maximum(idx_hi - np.arange(n), 1)
    x = np.sqrt(fwd / fwd.mean())
    # robust normalization: giant bursts clip at the top instead of compressing the
    # typical dynamic range to nothing (critical for the Spain/Uruguay matches)
    q10, q90 = np.quantile(x, 0.10), np.quantile(x, 0.90)
    # floor the range so a flat-walk seed does not amplify micro-fluctuations
    fnorm = np.clip((x - q10) / max(q90 - q10, 0.15), 0.0, 1.25) / 1.25
    # level spans ~0.30-0.60: "the sentiment is above 0.4 for most part of the
    # matches" (Fig 2), leaving the saturated plateau a >= 50% relative rise.
    s_curve = 0.26 + 0.26 * fnorm

    # small minute-scale sentiment noise
    noise_min = np.repeat(rng.normal(0.0, minute_noise, size=n // 60 + 1), 60)[:n]
    s_curve = s_curve + noise_min

    # ---- planted early-warning jumps (Fig 3) ---------------------------------------
    # During the warning window the curve saturates to ``sentiment_high`` and the
    # per-tweet noise collapses -- the first tweets about a notorious event are
    # uniformly polarized -- so the 120 s-window mean rises by >= 0.5 (the paper's
    # appdata trigger) over the pre-event baseline.  Window/tick misalignment still
    # produces occasional misses, matching the paper's own false negatives (§V-B).
    sigma_sec = np.full(n, tweet_noise)
    t_axis = np.arange(n, dtype=np.float64)

    def _plant(t0: int, hold_until: int) -> None:
        """Saturate [t0, hold_until), then decay back to baseline over ~3 min --
        sentiment stays elevated *through* the burst (this is also what keeps the
        lag-10 correlation of Table I high)."""
        hold_until = min(hold_until, n)
        s_curve[t0:hold_until] = sentiment_high
        sigma_sec[t0:hold_until] = 0.03
        tail = np.exp(-(t_axis[hold_until:] - hold_until) / 420.0)
        cut = min(hold_until + 1500, n)
        blend = (sentiment_high - s_curve[hold_until:cut]) * tail[: cut - hold_until]
        s_curve[hold_until:cut] += np.maximum(blend, 0.0)

    signal_times = []
    for onset in onsets:
        if rng.random() < signal_false_negative_rate:
            continue  # false negative: burst with no preceding sentiment spike
        lead = int(rng.uniform(120.0, 170.0))
        t0 = max(int(onset) - lead, 0)
        _plant(t0, int(onset) + 60)
        signal_times.append(t0)
    for _ in range(n_false_positives):
        t0 = int(rng.uniform(0.1, 0.9) * n)
        if min((abs(t0 - int(o)) for o in onsets), default=10**9) < 300:
            continue  # too close to a real burst to count as a false positive
        _plant(t0, t0 + 180)
        signal_times.append(t0)

    sec_idx = np.minimum(post_time.astype(np.int64), n - 1)
    sent = s_curve[sec_idx]
    sent = np.clip(sent + rng.normal(0.0, 1.0, size=total) * sigma_sec[sec_idx], 0.0, 1.0)

    return Trace(
        match=match,
        post_time=post_time,
        class_id=class_id[order],
        cycles=cycles[order],
        sentiment=sent.astype(np.float32),
        burst_times=onsets.astype(np.float64),
        signal_times=np.array(sorted(signal_times), dtype=np.float64),
        per_second_rate=lam,
    )


__all__ = ["MatchSpec", "MATCHES", "Trace", "generate_trace", "CLASSES"]
