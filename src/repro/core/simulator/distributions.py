"""Per-class service-demand distributions, calibrated to the paper's testbed.

Paper §IV-A: a tracer on the real IBM Streams sentiment-analysis application showed

  * tweets fall into *classes* = the path taken through the operator graph (Fig 1);
  * per-class processing *delay* on a loaded 1-CPU 2.6 GHz testbed is Weibull
    (NRMSE 0.01 for the off-topic class, Fig 6); tweets discarded by PE(1) have
    (effectively) zero delay;
  * steady state on that testbed: L = 15 875.32 tweets in flight,
    W = 192.09 s mean delay, lambda = 82.65 tweets/s -- consistent with Little's law
    (L = lambda * W = 15 876.24);
  * CPU utilization averaged 97.95%, and "if it is assumed that CPU cycles are
    uniformly distributed to the tweets, there is a reasonable way to convert those
    delay distributions to CPU cycles distributions".

That conversion is what this module implements: with L tweets egalitarian-sharing a
2.6 GHz core at 97.95% utilization, each in-flight tweet receives

  share = FREQ * UTIL / L  =  2.6e9 * 0.9795 / 15875.32  ~=  160.4e3 cycles/s,

so a tweet observed with delay ``d`` seconds demanded ``d * share`` cycles.  The
simulator then runs entirely in the cycles domain, which "allows the extrapolation
of the experiments to other machine configurations" (the simulations use 2.0 GHz
CPUs, Table III).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

# --- Testbed constants (paper §IV-A) -------------------------------------------------
TESTBED_FREQ_HZ = 2.6e9
TESTBED_UTILIZATION = 0.9795
TESTBED_IN_FLIGHT = 15_875.32          # L
TESTBED_MEAN_DELAY_S = 192.09          # W
TESTBED_INPUT_RATE = 82.65             # lambda (tweets/s)

#: cycles/second an in-flight tweet received on the testbed (uniform-share assumption)
CYCLES_PER_DELAY_SECOND = TESTBED_FREQ_HZ * TESTBED_UTILIZATION / TESTBED_IN_FLIGHT


@dataclass(frozen=True)
class TweetClass:
    """One path through the operator graph (Fig 1) and its delay model."""

    name: str
    weight: float                  # a-priori proportion of tweets taking this path
    mean_delay_s: float            # mean testbed delay; 0 => the PE(1) discard path
    weibull_shape: float = 1.7

    @property
    def weibull_scale(self) -> float:
        if self.mean_delay_s == 0.0:
            return 0.0
        return self.mean_delay_s / math.gamma(1.0 + 1.0 / self.weibull_shape)

    def delay_quantile(self, q: float) -> float:
        """Inverse CDF of the testbed-delay Weibull (seconds)."""
        if self.mean_delay_s == 0.0:
            return 0.0
        return self.weibull_scale * (-math.log1p(-q)) ** (1.0 / self.weibull_shape)

    def cycles_quantile(self, q: float) -> float:
        return self.delay_quantile(q) * CYCLES_PER_DELAY_SECOND

    def sample_cycles(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.mean_delay_s == 0.0:
            return np.zeros(n, dtype=np.float64)
        d = self.weibull_scale * rng.weibull(self.weibull_shape, size=n)
        return d * CYCLES_PER_DELAY_SECOND


def _calibrated_classes() -> tuple[TweetClass, ...]:
    """Class mixture whose overall mean delay is exactly W = 192.09 s.

    The paper gives the class *structure* (PE(1)-discards ~ zero delay; "most tweets
    are discarded" before full analysis; off-topic is the dominant class) but not the
    exact per-class means, so the non-zero means below are chosen in the observed
    band and then rescaled so the mixture mean matches the published W exactly.
    """
    raw = [
        # name                weight  mean-delay  shape
        ("pe1_discard",        0.10,       0.0,   1.7),   # "delay ... below 1 second"
        ("offtopic_discard",   0.55,     180.0,   1.15),  # Fig 6 class
        ("analyzed_discard",   0.20,     240.0,   1.10),
        ("full_pipeline",      0.15,     300.0,   1.05),
    ]
    # Shapes near 1 give the heavy-ish tails under which the load algorithm's
    # quantile pessimism (~9x the mean at q=99.999%) provides the early-trigger
    # head-room the paper describes; the per-class means/shapes are not published,
    # only the mixture mean (W = 192.09 s) and the Weibull family are.
    mix_mean = sum(w * m for _, w, m, _ in raw)
    scale = TESTBED_MEAN_DELAY_S / mix_mean
    return tuple(
        TweetClass(name, w, m * scale, k) for name, w, m, k in raw
    )


CLASSES: tuple[TweetClass, ...] = _calibrated_classes()


class ServiceModel:
    """A-priori knowledge of the service-demand distributions (used by `load`)."""

    def __init__(self, classes: tuple[TweetClass, ...] = CLASSES):
        if abs(sum(c.weight for c in classes) - 1.0) > 1e-9:
            raise ValueError("class weights must sum to 1")
        self.classes = classes
        self._weights = np.array([c.weight for c in classes])

    # -- used by the trace generator ---------------------------------------------------
    def sample_classes(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.choice(len(self.classes), size=n, p=self._weights).astype(np.int8)

    def sample_cycles(self, rng: np.random.Generator, class_ids: np.ndarray) -> np.ndarray:
        out = np.zeros(class_ids.shape[0], dtype=np.float64)
        for i, c in enumerate(self.classes):
            mask = class_ids == i
            n = int(mask.sum())
            if n:
                out[mask] = c.sample_cycles(rng, n)
        return out

    # -- used by the `load` auto-scaling algorithm -------------------------------------
    def quantile_cycles(self, q: float) -> float:
        """Class-weighted quantile of the service demand, in cycles.

        Paper §IV-C: "The estimated delay is calculated from the quantile function of
        the delay distribution of the different tweet classes and from the proportion
        of the class length.  [...] Each class estimated delay is then weighted
        according to the class length known from the training data."
        """
        return float(sum(c.weight * c.cycles_quantile(q) for c in self.classes))

    def mean_cycles(self) -> float:
        return float(
            sum(c.weight * c.mean_delay_s for c in self.classes) * CYCLES_PER_DELAY_SECOND
        )
