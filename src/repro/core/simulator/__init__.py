from repro.core.simulator.distributions import CLASSES, ServiceModel, TweetClass
from repro.core.simulator.engine import Engine, SimConfig, SimResult, repeat_until_ci, run_scenario
from repro.core.simulator.workload import MATCHES, MatchSpec, Trace, generate_trace

__all__ = [
    "CLASSES", "ServiceModel", "TweetClass",
    "Engine", "SimConfig", "SimResult", "run_scenario", "repeat_until_ci",
    "MATCHES", "MatchSpec", "Trace", "generate_trace",
]
