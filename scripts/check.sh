#!/usr/bin/env bash
# One verify entrypoint for builders:
#   tier-1 test suite  +  fast benchmark smoke pass (control-plane paths).
# Usage:  bash scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint: ruff (errors + unused imports; see ruff.toml) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks
else
  echo "ruff not installed; skipping (CI installs it via requirements.txt)"
fi

echo
echo "== tier-1: pytest =="
python -m pytest -x -q

echo
echo "== smoke: benchmarks =="
python -m benchmarks.run --smoke

echo
echo "== smoke: serving engine (trace-count gates + tokens/s floor vs the"
echo "==        pre-device-resident-loop baseline; writes BENCH_serving.json) =="
timeout 300 python -m benchmarks.run --smoke --only serving_engine

echo
echo "check.sh: ALL OK"
