#!/usr/bin/env bash
# One verify entrypoint for builders:
#   lint (ruff + replint)  +  tier-1 test suite  +  benchmark smoke pass.
#
# Usage:
#   bash scripts/check.sh           # full gate (lint + pytest + benchmarks)
#   bash scripts/check.sh --fast    # lint + pytest only, for quick local loops
#
# replint is the project-specific static-analysis gate (trace-safety,
# Pallas kernel rules, control-plane invariants):
#   PYTHONPATH=src python -m repro.lint src tests benchmarks
# Suppress a finding inline with `# replint: disable=RULE -- reason`;
# see DESIGN.md "The static-analysis gate" and `python -m repro.lint
# --list-rules`.  The JSON report lands in benchmarks/artifacts/ and is
# uploaded by CI.
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint: ruff (errors, unused imports/locals, redefinitions) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks
else
  echo "ruff not installed; skipping (CI installs it via requirements.txt)"
fi

echo
echo "== lint: replint selftest (every rule fires on its fixture corpus) =="
python -m repro.lint --selftest -q

echo
echo "== lint: replint (trace-safety + Pallas + control-plane rules) =="
python -m repro.lint src tests benchmarks \
  --json benchmarks/artifacts/replint_report.json

echo
echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "$FAST" == "1" ]]; then
  echo
  echo "== smoke: serving engine quick (perf gates: 1.5x tokens/s floor, bursty"
  echo "==        TTFT, single mixed trace; writes BENCH_serving.json) =="
  timeout 300 env BENCH_QUICK=1 python -m benchmarks.serving_engine
  echo
  echo "== smoke: chaos drills quick (5 scripted incidents imperative-vs-"
  echo "==        converger + 2 real-fleet drills, invariants hard-fail;"
  echo "==        writes chaos_drills.json) =="
  timeout 900 env BENCH_QUICK=1 python -m benchmarks.chaos_drills
  echo
  echo "check.sh: FAST OK (lint + pytest + quick serving/chaos benches)"
  exit 0
fi

echo
echo "== smoke: benchmarks =="
python -m benchmarks.run --smoke

echo
echo "== smoke: serving engine (trace-count gates + tokens/s and bursty-TTFT"
echo "==        floors vs the pre-overlap baseline; writes BENCH_serving.json) =="
timeout 300 python -m benchmarks.run --smoke --only serving_engine

echo
echo "== smoke: replica fleet (2-replica 1.5x aggregate tokens/s floor, bit-"
echo "==        identical drain migration, spawn-measured provisioning delay;"
echo "==        writes BENCH_fleet.json) =="
timeout 420 env BENCH_QUICK=1 python -m benchmarks.fleet_serving

echo
echo "== smoke: chaos drills (5 scripted incidents imperative-vs-converger +"
echo "==        2 real-fleet drills, invariant battery + byte-identical audit"
echo "==        re-runs hard-fail; writes chaos_drills.json) =="
timeout 900 env BENCH_QUICK=1 python -m benchmarks.chaos_drills

echo
echo "check.sh: ALL OK"
