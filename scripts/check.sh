#!/usr/bin/env bash
# One verify entrypoint for builders:
#   tier-1 test suite  +  fast benchmark smoke pass (control-plane paths).
# Usage:  bash scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo
echo "== smoke: benchmarks =="
python -m benchmarks.run --smoke

echo
echo "== smoke: serving engine (trace-count gates + tokens/s floor vs the"
echo "==        pre-device-resident-loop baseline; writes BENCH_serving.json) =="
timeout 300 python -m benchmarks.run --smoke --only serving_engine

echo
echo "check.sh: ALL OK"
